"""Fleet-simulator invariants (hypothesis + fixed-case) and regression.

Each property lives in a plain ``_check_*`` helper; the hypothesis
wrapper searches the space when hypothesis is installed, and a small
parametrized fixed-case test keeps the invariant exercised even where
hypothesis is absent (tests/conftest.py skips only the @given tests).
"""
import hashlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.telemetry import (
    DeviceProfile,
    generate_fleet,
    poisson_arrivals,
)
from repro.serving.fleet_sim import SimConfig, run_fleet_sim
from repro.serving.simulator import (
    CALIBRATED,
    POLICIES,
    fleet_sim_table4,
    run_table4,
)


# --------------------------------------------------------------------------
# Steady-state convergence: event-driven GPU-seconds == static Table 4
# --------------------------------------------------------------------------
def _check_table4_convergence(seed: int, rate: float):
    static = {k: v.total_gpu_time
              for k, v in run_table4(1000, seed=seed).items()}
    dyn = fleet_sim_table4(rate=rate, duration=120.0, seed=seed,
                           gpus_init=24, max_gpus=256)
    for policy in POLICIES:
        got = dyn[policy]["gpu_time_per_1000"]
        want = static[policy]
        assert abs(got - want) / want < 0.05, (
            f"{policy}: dynamic {got:.2f} vs static {want:.2f} GPU-s/1000 "
            f"(> 5% apart)")


def test_steady_state_gpu_seconds_match_table4():
    """Acceptance criterion: all four policies within 5% of run_table4."""
    _check_table4_convergence(seed=0, rate=25.0)


@given(seed=st.integers(0, 3), rate=st.sampled_from([15.0, 25.0, 40.0]))
@settings(max_examples=6, deadline=None)
def test_steady_state_convergence_property(seed, rate):
    _check_table4_convergence(seed, rate)


# --------------------------------------------------------------------------
# Physical lower bound: nothing completes faster than network + compute
# --------------------------------------------------------------------------
def _check_lower_bound(seed: int, rate: float, policy: str):
    cfg = SimConfig(policy=policy, rate=rate, duration=30.0, seed=seed,
                    gpus_init=8, max_gpus=64)
    res = run_fleet_sim(cfg)
    assert res.completed, "simulation produced no completions"
    for c in res.completed:
        assert c.latency >= c.lower_bound - 1e-6, (
            f"{c.request_id} finished in {c.latency:.4f}s, below its "
            f"network+compute floor {c.lower_bound:.4f}s")
        assert c.completion >= c.arrival


@pytest.mark.parametrize("policy", POLICIES)
def test_lower_bound_fixed(policy):
    _check_lower_bound(seed=1, rate=10.0, policy=policy)


@given(seed=st.integers(0, 10), rate=st.floats(2.0, 30.0),
       policy=st.sampled_from(POLICIES))
@settings(max_examples=15, deadline=None)
def test_lower_bound_property(seed, rate, policy):
    _check_lower_bound(seed, rate, policy)


# --------------------------------------------------------------------------
# Monotonicity: violations non-decreasing in arrival rate.
#
# Rigorous coupling: a homogeneous fleet (device identity can't differ
# across rates), a FIXED pool (no autoscaler feedback), and nested
# arrival streams (poisson_arrivals thins a shared master process, so a
# higher rate only ADDS arrivals to a FIFO queue — it can never complete
# an original request earlier).  Batching is excluded: a new peer can
# flush an original's window early, which legitimately breaks sample-
# wise monotonicity.
# --------------------------------------------------------------------------
_MONO_POLICIES = ("all_cloud", "constant", "variable")


def _check_violations_monotone(seed: int, policy: str):
    fleet = [DeviceProfile(device_id=f"d{i}", r_dev=2.25,
                           k_decode=CALIBRATED.k_decode)
             for i in range(8)]
    rates = (10.0, 25.0, 50.0)
    viols = []
    for rate in rates:
        cfg = SimConfig(policy=policy, rate=rate, max_rate=max(rates),
                        duration=60.0, seed=seed, fleet=fleet,
                        gpus_init=10, autoscale=False)
        viols.append(run_fleet_sim(cfg).violations)
    assert viols == sorted(viols), (
        f"{policy}: violations {viols} not non-decreasing over rates "
        f"{rates}")


@pytest.mark.parametrize("policy", _MONO_POLICIES)
def test_violations_monotone_fixed(policy):
    _check_violations_monotone(seed=0, policy=policy)


@given(seed=st.integers(0, 20), policy=st.sampled_from(_MONO_POLICIES))
@settings(max_examples=12, deadline=None)
def test_violations_monotone_property(seed, policy):
    _check_violations_monotone(seed, policy)


# --------------------------------------------------------------------------
# Arrival-process properties
# --------------------------------------------------------------------------
def test_poisson_arrivals_nested():
    """max_rate thinning makes streams nested: low-rate arrivals are a
    subset of high-rate arrivals at the same (seed, max_rate)."""
    hi = list(poisson_arrivals(20.0, 50.0, seed=3, max_rate=20.0))
    lo = list(poisson_arrivals(5.0, 50.0, seed=3, max_rate=20.0))
    assert set(lo) <= set(hi)
    assert len(lo) < len(hi)
    assert all(b > a for a, b in zip(hi, hi[1:]))   # strictly increasing


def test_poisson_rate_exceeding_master_rejected():
    with pytest.raises(ValueError):
        list(poisson_arrivals(30.0, 10.0, seed=0, max_rate=20.0))


# --------------------------------------------------------------------------
# Batching-window / autoscaler behavior
# --------------------------------------------------------------------------
def test_batching_windows_pair_requests():
    """Homogeneous fleet + high rate: nearly everything pairs, and
    batched requests cost c_batch/2 of a solo run's GPU time.

    r_dev=2.5 -> n_final=35 whose batched-rate latency (~8.0s) sits
    inside t_lim=8.5s, so §4.4 admission lets requests wait."""
    fleet = [DeviceProfile(device_id="d", r_dev=2.5,
                           k_decode=CALIBRATED.k_decode)]
    # pool provisioned for the load from t=0: otherwise the cold-start
    # queue makes admission (correctly) refuse window waits and the
    # requests run solo
    cfg = SimConfig(policy="variable+batching", rate=40.0, duration=30.0,
                    seed=2, fleet=fleet, gpus_init=40, max_gpus=64)
    res = run_fleet_sim(cfg)
    assert res.batched_fraction() > 0.9
    batched = [c for c in res.completed if c.batched]
    solo = [c for c in res.completed if not c.batched]
    assert batched
    n = batched[0].n_final
    p = cfg.params
    want = n * p.c_batch / p.r_cloud / 2.0
    assert abs(batched[0].gpu_seconds - want) < 1e-9
    if solo:
        assert abs(solo[0].gpu_seconds - n / p.r_cloud) < 1e-9


def test_autoscaler_grows_and_releases():
    """A burst wave must grow the pool; the trough must release GPUs
    (§4.5 over-subscription: capacity goes back to production jobs)."""
    cfg = SimConfig(policy="variable", process="bursty", rate=20.0,
                    duration=120.0, seed=4, gpus_init=2, max_gpus=64,
                    min_gpus=2)
    res = run_fleet_sim(cfg)
    assert res.peak_gpus > cfg.gpus_init
    assert res.released_gpus > 0
    assert any(s["gpus"] < res.peak_gpus for s in res.timeseries)


def test_local_only_requests_use_no_cloud():
    """Devices fast enough to meet the SLA alone (n_final == 0) must not
    consume GPU-seconds."""
    p = CALIBRATED
    fast = [DeviceProfile(device_id="fast", r_dev=50.0, k_decode=p.k_decode)]
    cfg = SimConfig(policy="variable", rate=5.0, duration=20.0, seed=0,
                    fleet=fast, gpus_init=2)
    res = run_fleet_sim(cfg)
    assert res.completed
    assert res.total_gpu_seconds == 0.0
    assert all(c.n_final == 0 and c.gpu_seconds == 0.0
               for c in res.completed)


def test_timeseries_emitted_and_consistent():
    cfg = SimConfig(policy="variable+batching", rate=15.0, duration=60.0,
                    seed=0, gpus_init=12, metrics_interval_s=5.0)
    res = run_fleet_sim(cfg)
    assert len(res.timeseries) >= 10
    for snap in res.timeseries:
        assert snap["gpus"] >= snap["gpus_busy"] >= 0
        assert 0.0 <= snap["utilization"] <= 1.0 + 1e-9
        assert snap["completed"] + snap["in_flight"] == snap["arrivals"]
    # monotone counters
    for a, b in zip(res.timeseries, res.timeseries[1:]):
        assert b["arrivals"] >= a["arrivals"]
        assert b["violations"] >= a["violations"]
        assert b["gpu_seconds"] >= a["gpu_seconds"] - 1e-12


# --------------------------------------------------------------------------
# Seeded golden-trace regression
# --------------------------------------------------------------------------
def test_golden_trace():
    """Full end-to-end determinism: same seed -> same event trace.

    Guards against accidental changes to event ordering, window
    semantics, or the pool model.  If a deliberate semantic change moves
    these numbers, re-record them (instructions in docs/fleet_sim.md).
    """
    cfg = SimConfig(policy="variable+batching", rate=12.0, duration=40.0,
                    seed=7, gpus_init=10, max_gpus=32,
                    metrics_interval_s=10.0)
    res = run_fleet_sim(cfg)
    sig = hashlib.sha256()
    for c in res.completed:
        sig.update(f"{c.request_id}:{c.completion:.9f}:{c.batched:d};"
                   .encode())
    golden = {
        "n_arrivals": res.n_arrivals,
        "n_completed": len(res.completed),
        "violations": res.violations,
        "gpu_seconds": round(res.total_gpu_seconds, 9),
        "p99": round(res.latency_percentile(99), 9),
        "digest": sig.hexdigest()[:16],
    }
    expected = {
        "n_arrivals": 490,
        "n_completed": 490,
        "violations": 0,
        "gpu_seconds": 249.312,
        "p99": 8.4873321,
        "digest": "af766f3924e39378",
    }
    assert golden == expected
