"""Fleet-simulator invariants (hypothesis + fixed-case) and regression.

Each property lives in a plain ``_check_*`` helper; the hypothesis
wrapper searches the space when hypothesis is installed, and a small
parametrized fixed-case test keeps the invariant exercised even where
hypothesis is absent (tests/conftest.py skips only the @given tests).
"""
import hashlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.telemetry import (
    DeviceProfile,
    generate_fleet,
    poisson_arrivals,
)
from repro.serving.fleet_sim import SimConfig, run_fleet_sim
from repro.serving.simulator import (
    CALIBRATED,
    POLICIES,
    fleet_sim_table4,
    run_table4,
)


# --------------------------------------------------------------------------
# Steady-state convergence: event-driven GPU-seconds == static Table 4
# --------------------------------------------------------------------------
def _check_table4_convergence(seed: int, rate: float):
    static = {k: v.total_gpu_time
              for k, v in run_table4(1000, seed=seed).items()}
    dyn = fleet_sim_table4(rate=rate, duration=120.0, seed=seed,
                           gpus_init=24, max_gpus=256)
    for policy in POLICIES:
        got = dyn[policy]["gpu_time_per_1000"]
        want = static[policy]
        assert abs(got - want) / want < 0.05, (
            f"{policy}: dynamic {got:.2f} vs static {want:.2f} GPU-s/1000 "
            f"(> 5% apart)")


def test_steady_state_gpu_seconds_match_table4():
    """Acceptance criterion: all four policies within 5% of run_table4."""
    _check_table4_convergence(seed=0, rate=25.0)


@given(seed=st.integers(0, 3), rate=st.sampled_from([15.0, 25.0, 40.0]))
@settings(max_examples=6, deadline=None)
def test_steady_state_convergence_property(seed, rate):
    _check_table4_convergence(seed, rate)


# --------------------------------------------------------------------------
# Physical lower bound: nothing completes faster than network + compute
# --------------------------------------------------------------------------
def _check_lower_bound(seed: int, rate: float, policy: str):
    cfg = SimConfig(policy=policy, rate=rate, duration=30.0, seed=seed,
                    gpus_init=8, max_gpus=64)
    res = run_fleet_sim(cfg)
    assert res.completed, "simulation produced no completions"
    for c in res.completed:
        assert c.latency >= c.lower_bound - 1e-6, (
            f"{c.request_id} finished in {c.latency:.4f}s, below its "
            f"network+compute floor {c.lower_bound:.4f}s")
        assert c.completion >= c.arrival


@pytest.mark.parametrize("policy", POLICIES)
def test_lower_bound_fixed(policy):
    _check_lower_bound(seed=1, rate=10.0, policy=policy)


@given(seed=st.integers(0, 10), rate=st.floats(2.0, 30.0),
       policy=st.sampled_from(POLICIES))
@settings(max_examples=15, deadline=None)
def test_lower_bound_property(seed, rate, policy):
    _check_lower_bound(seed, rate, policy)


# --------------------------------------------------------------------------
# Monotonicity: violations non-decreasing in arrival rate.
#
# Rigorous coupling: a homogeneous fleet (device identity can't differ
# across rates), a FIXED pool (no autoscaler feedback), and nested
# arrival streams (poisson_arrivals thins a shared master process, so a
# higher rate only ADDS arrivals to a FIFO queue — it can never complete
# an original request earlier).  Batching is excluded: a new peer can
# flush an original's window early, which legitimately breaks sample-
# wise monotonicity.
# --------------------------------------------------------------------------
_MONO_POLICIES = ("all_cloud", "constant", "variable")


def _check_violations_monotone(seed: int, policy: str):
    fleet = [DeviceProfile(device_id=f"d{i}", r_dev=2.25,
                           k_decode=CALIBRATED.k_decode)
             for i in range(8)]
    rates = (10.0, 25.0, 50.0)
    viols = []
    for rate in rates:
        cfg = SimConfig(policy=policy, rate=rate, max_rate=max(rates),
                        duration=60.0, seed=seed, fleet=fleet,
                        gpus_init=10, autoscale=False)
        viols.append(run_fleet_sim(cfg).violations)
    assert viols == sorted(viols), (
        f"{policy}: violations {viols} not non-decreasing over rates "
        f"{rates}")


@pytest.mark.parametrize("policy", _MONO_POLICIES)
def test_violations_monotone_fixed(policy):
    _check_violations_monotone(seed=0, policy=policy)


@given(seed=st.integers(0, 20), policy=st.sampled_from(_MONO_POLICIES))
@settings(max_examples=12, deadline=None)
def test_violations_monotone_property(seed, policy):
    _check_violations_monotone(seed, policy)


# --------------------------------------------------------------------------
# Arrival-process properties
# --------------------------------------------------------------------------
def test_poisson_arrivals_nested():
    """max_rate thinning makes streams nested: low-rate arrivals are a
    subset of high-rate arrivals at the same (seed, max_rate)."""
    hi = list(poisson_arrivals(20.0, 50.0, seed=3, max_rate=20.0))
    lo = list(poisson_arrivals(5.0, 50.0, seed=3, max_rate=20.0))
    assert set(lo) <= set(hi)
    assert len(lo) < len(hi)
    assert all(b > a for a, b in zip(hi, hi[1:]))   # strictly increasing


def test_poisson_rate_exceeding_master_rejected():
    with pytest.raises(ValueError):
        list(poisson_arrivals(30.0, 10.0, seed=0, max_rate=20.0))


# --------------------------------------------------------------------------
# Batching-window / autoscaler behavior
# --------------------------------------------------------------------------
def test_batching_windows_pair_requests():
    """Homogeneous fleet + high rate: nearly everything pairs, and
    batched requests cost c_batch/2 of a solo run's GPU time.

    r_dev=2.5 -> n_final=35 whose batched-rate latency (~8.0s) sits
    inside t_lim=8.5s, so §4.4 admission lets requests wait."""
    fleet = [DeviceProfile(device_id="d", r_dev=2.5,
                           k_decode=CALIBRATED.k_decode)]
    # pool provisioned for the load from t=0: otherwise the cold-start
    # queue makes admission (correctly) refuse window waits and the
    # requests run solo
    cfg = SimConfig(policy="variable+batching", rate=40.0, duration=30.0,
                    seed=2, fleet=fleet, gpus_init=40, max_gpus=64)
    res = run_fleet_sim(cfg)
    assert res.batched_fraction() > 0.9
    batched = [c for c in res.completed if c.batched]
    solo = [c for c in res.completed if not c.batched]
    assert batched
    n = batched[0].n_final
    p = cfg.params
    want = n * p.c_batch / p.r_cloud / 2.0
    assert abs(batched[0].gpu_seconds - want) < 1e-9
    if solo:
        assert abs(solo[0].gpu_seconds - n / p.r_cloud) < 1e-9


def test_autoscaler_grows_and_releases():
    """A burst wave must grow the pool; the trough must release GPUs
    (§4.5 over-subscription: capacity goes back to production jobs)."""
    cfg = SimConfig(policy="variable", process="bursty", rate=20.0,
                    duration=120.0, seed=4, gpus_init=2, max_gpus=64,
                    min_gpus=2)
    res = run_fleet_sim(cfg)
    assert res.peak_gpus > cfg.gpus_init
    assert res.released_gpus > 0
    assert any(s["gpus"] < res.peak_gpus for s in res.timeseries)


def test_local_only_requests_use_no_cloud():
    """Devices fast enough to meet the SLA alone (n_final == 0) must not
    consume GPU-seconds."""
    p = CALIBRATED
    fast = [DeviceProfile(device_id="fast", r_dev=50.0, k_decode=p.k_decode)]
    cfg = SimConfig(policy="variable", rate=5.0, duration=20.0, seed=0,
                    fleet=fast, gpus_init=2)
    res = run_fleet_sim(cfg)
    assert res.completed
    assert res.total_gpu_seconds == 0.0
    assert all(c.n_final == 0 and c.gpu_seconds == 0.0
               for c in res.completed)


def test_timeseries_emitted_and_consistent():
    cfg = SimConfig(policy="variable+batching", rate=15.0, duration=60.0,
                    seed=0, gpus_init=12, metrics_interval_s=5.0)
    res = run_fleet_sim(cfg)
    assert len(res.timeseries) >= 10
    for snap in res.timeseries:
        assert snap["gpus"] >= snap["gpus_busy"] >= 0
        assert 0.0 <= snap["utilization"] <= 1.0 + 1e-9
        assert snap["completed"] + snap["in_flight"] == snap["arrivals"]
    # monotone counters
    for a, b in zip(res.timeseries, res.timeseries[1:]):
        assert b["arrivals"] >= a["arrivals"]
        assert b["violations"] >= a["violations"]
        assert b["gpu_seconds"] >= a["gpu_seconds"] - 1e-12


# --------------------------------------------------------------------------
# Seeded golden-trace regression
# --------------------------------------------------------------------------
def test_golden_trace():
    """Full end-to-end determinism: same seed -> same event trace.

    Guards against accidental changes to event ordering, window
    semantics, or the pool model.  If a deliberate semantic change moves
    these numbers, re-record them (instructions in docs/fleet_sim.md).
    """
    cfg = SimConfig(policy="variable+batching", rate=12.0, duration=40.0,
                    seed=7, gpus_init=10, max_gpus=32,
                    metrics_interval_s=10.0)
    res = run_fleet_sim(cfg)
    sig = hashlib.sha256()
    for c in res.completed:
        sig.update(f"{c.request_id}:{c.completion:.9f}:{c.batched:d};"
                   .encode())
    golden = {
        "n_arrivals": res.n_arrivals,
        "n_completed": len(res.completed),
        "violations": res.violations,
        "gpu_seconds": round(res.total_gpu_seconds, 9),
        "p99": round(res.latency_percentile(99), 9),
        "digest": sig.hexdigest()[:16],
    }
    expected = {
        "n_arrivals": 490,
        "n_completed": 490,
        "violations": 0,
        "gpu_seconds": 249.312,
        "p99": 8.4873321,
        "digest": "af766f3924e39378",
    }
    assert golden == expected


# --------------------------------------------------------------------------
# EDF dispatch: never more SLA violations than FIFO on the same trace.
#
# Same rigorous coupling idea as the monotonicity tests: a FIXED pool (no
# autoscaler feedback), no batching windows (policy "variable", so the
# submitted job sequence is identical across dispatch modes and only the
# dequeue order differs), and a shared seed so both runs see the exact
# same arrival trace.  The EDF pool ships overload shedding (doomed jobs
# yield to winnable ones) — plain EDF would NOT satisfy this under
# sustained overload, which is why the dispatcher implements shedding.
# --------------------------------------------------------------------------
def _check_edf_no_worse_than_fifo(seed: int, rate: float, gpus: int):
    fleet = [DeviceProfile(device_id=f"d{i}", r_dev=r,
                           k_decode=CALIBRATED.k_decode)
             for i, r in enumerate((1.7, 2.0, 2.25, 2.6, 3.0))]
    viols = {}
    for dispatch in ("fifo", "edf"):
        cfg = SimConfig(policy="variable", rate=rate, max_rate=50.0,
                        duration=60.0, seed=seed, fleet=fleet,
                        gpus_init=gpus, autoscale=False, dispatch=dispatch)
        viols[dispatch] = run_fleet_sim(cfg).violations
    assert viols["edf"] <= viols["fifo"], (
        f"EDF produced MORE violations ({viols['edf']}) than FIFO "
        f"({viols['fifo']}) at seed={seed} rate={rate} gpus={gpus}")


@pytest.mark.parametrize("rate,gpus", [(15.0, 8), (25.0, 5), (40.0, 12)])
def test_edf_no_worse_than_fifo_fixed(rate, gpus):
    _check_edf_no_worse_than_fifo(seed=0, rate=rate, gpus=gpus)


@given(seed=st.integers(0, 20), rate=st.sampled_from([15.0, 25.0, 40.0,
                                                      50.0]),
       gpus=st.sampled_from([5, 8, 12]))
@settings(max_examples=20, deadline=None)
def test_edf_no_worse_than_fifo_property(seed, rate, gpus):
    _check_edf_no_worse_than_fifo(seed, rate, gpus)


# --------------------------------------------------------------------------
# Heterogeneous capacity: 2-class pool (base + 0.5x spot)
# --------------------------------------------------------------------------
def _hetero_run(dispatch: str, seed: int = 0):
    from repro.serving.simulator import table4_capacity
    cap = table4_capacity(base_count=12, spot_count=20, base_max=12,
                          spot_max=20)
    cfg = SimConfig(policy="variable+batching", process="diurnal",
                    rate=20.0, duration=300.0, diurnal_period_s=300.0,
                    seed=seed, capacity=cap, dispatch=dispatch,
                    autoscale=False)
    return run_fleet_sim(cfg)


def test_hetero_edf_beats_fifo_at_equal_capacity():
    """Acceptance criterion: on the SAME provisioned 2-class pool under
    the diurnal trace, EDF + deadline-aware class routing yields strictly
    lower p99 than deadline-blind FIFO (and far fewer violations)."""
    fifo = _hetero_run("fifo")
    edf = _hetero_run("edf")
    assert edf.latency_percentile(99) < fifo.latency_percentile(99)
    assert edf.violations < fifo.violations
    # both ran on identical provisioned capacity (equal GPU cost to hold)
    assert edf.peak_gpus == fifo.peak_gpus == 32


def test_hetero_per_class_accounting():
    """Per-class GPU-seconds sum to the total; every completed request
    ran on a real class; weighted cost = share x class cost_weight."""
    res = _hetero_run("edf")
    class_names = set(res.per_class)
    assert class_names == {"base", "spot"}
    total = sum(v["gpu_seconds"] for v in res.per_class.values())
    assert abs(total - res.total_gpu_seconds) < 1e-6
    weights = {c.name: c.cost_weight for c in res.config.capacity}
    cost = 0.0
    for c in res.completed:
        if c.n_final > 0:
            assert c.gpu_class in class_names
            assert abs(c.gpu_cost
                       - c.gpu_seconds * weights[c.gpu_class]) < 1e-12
            cost += c.gpu_cost
    assert abs(cost - res.total_gpu_cost) < 1e-6
    # spot is strictly cheaper per GPU-second than base here
    assert weights["spot"] < weights["base"]


def test_hetero_spot_scales_first_and_releases_first():
    """§4.5 per-class autoscaling: growth lands on the preemptible class
    before the base grows beyond its floor, and the trough releases spot
    capacity back to production jobs."""
    from repro.core.capacity import CloudCapacity, GpuClass
    cap = CloudCapacity((
        GpuClass("base", r_cloud=CALIBRATED.r_cloud, count=4, min_count=4,
                 max_count=4),
        GpuClass("spot", r_cloud=CALIBRATED.r_cloud * 0.5, count=0,
                 preemptible=True, cost_weight=0.3, max_count=64),
    ))
    cfg = SimConfig(policy="variable", process="bursty", rate=20.0,
                    duration=120.0, seed=4, capacity=cap, dispatch="edf")
    res = run_fleet_sim(cfg)
    spot = res.per_class["spot"]
    base = res.per_class["base"]
    assert spot["peak"] > 0                 # the burst grew the spot slice
    assert spot["released"] > 0             # the trough released it
    assert base["peak"] == 4 and base["released"] == 0
    assert res.peak_gpus > 4


# --------------------------------------------------------------------------
# batch_size = 3 windows: triples form online and split GPU time 3 ways
# --------------------------------------------------------------------------
def test_batching_windows_batch3_triples():
    """batch_size=3: windows flush at 3 members; each member's share is
    c_batch_at(c2, 3)/3 of a solo run (the §4.4 linear micro-model)."""
    from repro.core.cost_model import c_batch_at
    fleet = [DeviceProfile(device_id="d", r_dev=2.5,
                           k_decode=CALIBRATED.k_decode)]
    cfg = SimConfig(policy="variable+batching", batch_size=3, rate=40.0,
                    duration=30.0, seed=2, fleet=fleet, gpus_init=40,
                    max_gpus=64)
    res = run_fleet_sim(cfg)
    batched = [c for c in res.completed if c.batched]
    assert batched, "no triples formed"
    p = cfg.params
    c3 = c_batch_at(p.c_batch, 3)
    n = batched[0].n_final
    full = [c for c in batched
            if abs(c.gpu_seconds - n * c3 / p.r_cloud / 3.0) < 1e-9]
    # most batched members rode full triples; partial flushes (2 members
    # at window expiry) pay c_batch_at(c2, 2)/2 instead
    assert len(full) > 0.5 * len(batched)
    for c in res.completed:
        if not c.batched:
            assert abs(c.gpu_seconds - c.n_final / p.r_cloud) < 1e-9


# --------------------------------------------------------------------------
# Adaptive SLA (§7): pressure relaxes t_lim instead of violating
# --------------------------------------------------------------------------
def test_adaptive_sla_relaxes_under_pressure():
    """Bursty overload on a capped pool: the §7 controller must relax
    t_lim (more device work per request), cutting BOTH violations and
    cloud GPU-seconds vs the fixed-SLA run."""
    kw = dict(policy="variable", process="bursty", rate=25.0,
              duration=180.0, seed=3, gpus_init=10, max_gpus=14,
              min_gpus=2, sla_ceil=30.0)
    fixed = run_fleet_sim(SimConfig(adaptive_sla=False, **kw))
    adapt = run_fleet_sim(SimConfig(adaptive_sla=True, **kw))
    assert adapt.final_t_lim > fixed.final_t_lim == CALIBRATED.t_lim
    assert adapt.violations < fixed.violations
    assert adapt.total_gpu_seconds < fixed.total_gpu_seconds
    # deadlines are contracts: in-flight requests keep the t_lim they
    # arrived with, so the timeseries records the evolving target
    tls = [s["t_lim"] for s in adapt.timeseries]
    assert max(tls) > CALIBRATED.t_lim


def test_edf_never_routes_to_empty_class():
    """Regression: a class with zero capacity and zero pending growth
    must never receive jobs — queueing there strands them forever (jobs
    never migrate between class queues) and the run would not
    terminate."""
    from repro.serving.simulator import table4_capacity
    cap = table4_capacity(base_count=8, spot_count=0, spot_max=20)
    for autoscale in (False, True):
        cfg = SimConfig(policy="variable", rate=5.0, duration=10.0,
                        seed=0, capacity=cap, dispatch="edf",
                        autoscale=autoscale)
        res = run_fleet_sim(cfg)                  # must terminate
        assert len(res.completed) == res.n_arrivals > 0
        spot = res.per_class["spot"]
        if spot["peak"] == 0:                     # never provisioned
            assert spot["gpu_seconds"] == 0.0
