"""Shared test config.

The property-based modules (`test_cost_model`, `test_scheduler`,
`test_segmentation`, `test_transport`, `test_fleet_sim`) import hypothesis
at module scope and build strategies at import time.  When hypothesis is
not installed (it is a dev-only dependency; see requirements-dev.txt)
those imports used to abort collection for the whole suite.  This
conftest installs a minimal stub *before* collection so that:

  * every module still collects (zero collection errors), and
  * each property-based test SKIPS with a clear message instead of
    erroring.

The stub only has to satisfy two usage patterns: strategy construction at
module import time (`st.builds(...)`, `hnp.arrays(...)`, chained
`.flatmap(...)` etc. — all return an inert chainable placeholder) and the
`@given(...)` / `@settings(...)` decorators (replace the test body with a
zero-argument skipper, so pytest never tries to resolve the strategy
parameters as fixtures).
"""
import sys
import types

import pytest


def _install_hypothesis_stub():
    class _Strategy:
        """Inert stand-in for hypothesis strategies: any attribute access
        or call (module-import-time strategy construction) returns another
        placeholder."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    _SKIP_MSG = ("hypothesis is not installed — property-based test "
                 "skipped (pip install -r requirements-dev.txt)")

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.skip(_SKIP_MSG)
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def example(*_args, **_kwargs):
        return lambda fn: fn

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.example = example
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.HealthCheck = _Strategy()
    hyp.__getattr__ = lambda name: _Strategy()

    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: _Strategy()

    extra = types.ModuleType("hypothesis.extra")
    hnp = types.ModuleType("hypothesis.extra.numpy")
    hnp.__getattr__ = lambda name: _Strategy()
    extra.numpy = hnp

    hyp.strategies = st
    hyp.extra = extra
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
    sys.modules["hypothesis.extra"] = extra
    sys.modules["hypothesis.extra.numpy"] = hnp


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
