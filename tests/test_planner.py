"""Unified Planner API: decision protocol, replay determinism,
planner-vs-legacy equivalence, deadline-aware allocation, and the
fitted batch-model calibration path (hypothesis + fixed-case, per
tests/conftest.py)."""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (
    CALIBRATED,
    POLICIES,
    BatchModel,
    CloudCapacity,
    DeviceProfile,
    GpuClass,
    NetworkProfile,
    PlanDecision,
    PlanRequest,
    Planner,
    SimConfig,
    allocate_gpus_heterogeneous,
    cheapest_feasible_class,
    deadline_floors,
    make_scheduler,
    replay,
    run_fleet_sim,
    run_table4,
    table4_capacity,
    table4_fleet,
)
from repro.core.cost_model import CostParams, c_batch_at, c_batch_of
from repro.core.scheduler import ScheduleSummary, group_workloads


def _planner(policy="variable+batching", capacity=None, **kw):
    return Planner(CALIBRATED, policy=policy, capacity=capacity, **kw)


def _request(r_dev=2.25, rtt=0.3, hint=0.0, rid="r0"):
    return PlanRequest(device=DeviceProfile("d0", r_dev=r_dev, rtt=rtt,
                                            k_decode=CALIBRATED.k_decode),
                       queue_delay_hint=hint, request_id=rid)


# --------------------------------------------------------------------------
# Decision protocol: JSON round-trip + deterministic replay
# --------------------------------------------------------------------------
def _check_roundtrip_and_replay(policy, r_dev, rtt, hint):
    planner = _planner(policy, capacity=table4_capacity(), dispatch="edf")
    d = planner.plan(_request(r_dev=r_dev, rtt=rtt, hint=hint))
    wire = json.dumps(d.to_json())                    # JSON-serializable
    back = PlanDecision.from_json(json.loads(wire))
    assert back.to_json() == d.to_json()              # round trip
    assert replay(wire).to_json() == d.to_json()      # deterministic replay
    # the reconstructed legacy Assignment matches the live one bit-exactly
    a, b = d.assignment(), back.assignment()
    assert (a.n_exact, a.n_final, a.latency, a.feasible) == \
        (b.n_exact, b.n_final, b.latency, b.feasible)


@pytest.mark.parametrize("policy", POLICIES)
def test_roundtrip_and_replay_fixed(policy):
    _check_roundtrip_and_replay(policy, r_dev=2.25, rtt=0.3, hint=0.25)


@given(policy=st.sampled_from(POLICIES),
       r_dev=st.floats(0.5, 6.0), rtt=st.floats(0.0, 1.0),
       hint=st.floats(0.0, 5.0))
@settings(max_examples=25, deadline=None)
def test_roundtrip_and_replay_property(policy, r_dev, rtt, hint):
    _check_roundtrip_and_replay(policy, r_dev, rtt, hint)


def test_replay_carries_adapted_sla():
    """set_t_lim (the §7 hook) folds into the serialized params, so a
    decision made under a relaxed SLA replays under that same SLA."""
    planner = _planner("variable")
    planner.set_t_lim(12.0)
    d = planner.plan(_request(r_dev=1.6))
    assert d.t_lim == 12.0
    assert replay(d.to_json()).to_json() == d.to_json()
    sla = [e for e in d.trace if e["field"] == "t_lim"]
    assert sla and sla[0]["policy"].startswith("sla:adaptive")


def test_explain_names_a_policy_per_field():
    d = _planner(capacity=table4_capacity()).plan(_request())
    traced = {e["field"] for e in d.trace}
    for field in ("n_exact", "n_final", "latency", "feasible",
                  "gpu_class", "gpu_time", "batch_admit", "t_lim"):
        assert field in traced
    assert all(e["policy"] for e in d.trace)
    text = d.explain()
    assert "split:variable+batching" in text
    assert "quantize:n_step=5" in text
    assert "batching:" in text


def test_network_profile_overrides_device_link():
    """Live network measurements beat the profile's last-reported rtt."""
    slow = _planner("variable").plan(_request(r_dev=2.25, rtt=0.3))
    fast = Planner(CALIBRATED, policy="variable").plan(PlanRequest(
        device=DeviceProfile("d0", r_dev=2.25, rtt=0.3,
                             k_decode=CALIBRATED.k_decode),
        network=NetworkProfile(rtt=0.05)))
    assert fast.n_final <= slow.n_final
    assert fast.request["network"]["rtt"] == 0.05
    assert replay(fast.to_json()).to_json() == fast.to_json()


# --------------------------------------------------------------------------
# Planner-vs-legacy equivalence on the Table-4 fleet
# --------------------------------------------------------------------------
def test_planner_matches_legacy_schedulers_on_table4_fleet():
    """Per-request planner output == the legacy scheduler objects the
    static Table-4 path runs, for every policy (bit-exact)."""
    fleet = table4_fleet(seed=0)
    for policy in POLICIES:
        sched = make_scheduler(policy, CALIBRATED, worst_rtt=fleet[0].rtt)
        planner = _planner(policy, worst_rtt=fleet[0].rtt)
        for prof in fleet[::37]:
            a = sched.assign_one(prof)
            d = planner.plan(PlanRequest(device=prof))
            assert d.n_exact == a.n_exact
            assert d.n_final == a.n_final
            assert d.latency == a.latency
            assert d.feasible == a.feasible


def test_planner_gpu_time_matches_table4_totals():
    """Summing planner-predicted GPU time over the fleet reproduces the
    static Table-4 totals bit-exactly for the non-batching policies
    (batching pairs over a snapshot, which a per-request plan can't)."""
    fleet = table4_fleet(seed=0)
    static = run_table4(1000, seed=0)
    for policy in ("all_cloud", "constant", "variable"):
        planner = _planner(policy, worst_rtt=fleet[0].rtt)
        total = sum(planner.plan(PlanRequest(device=p)).gpu_time
                    for p in fleet)
        assert total == pytest.approx(static[policy].total_gpu_time,
                                      rel=0, abs=1e-9)


def test_planner_advisory_route_matches_cheapest_feasible_class():
    cap = table4_capacity()
    planner = _planner("variable", capacity=cap)
    for r_dev in (1.5, 2.25, 3.0):
        d = planner.plan(_request(r_dev=r_dev))
        if d.n_final > 0:
            want = cheapest_feasible_class(d.n_final, r_dev, 0.3,
                                           planner.p, cap)
            assert d.gpu_class == want.name
            assert d.cloud_rate == want.r_cloud


def test_golden_trace_invariance_is_pinned():
    """The FIFO fleet_sim golden trace must be unchanged through the
    planner migration — same numbers test_golden_trace pins, asserted
    here against the planner-driven run via the facade imports."""
    import hashlib
    cfg = SimConfig(policy="variable+batching", rate=12.0, duration=40.0,
                    seed=7, gpus_init=10, max_gpus=32,
                    metrics_interval_s=10.0)
    res = run_fleet_sim(cfg)
    sig = hashlib.sha256()
    for c in res.completed:
        sig.update(f"{c.request_id}:{c.completion:.9f}:{c.batched:d};"
                   .encode())
    assert (res.n_arrivals, len(res.completed), res.violations,
            round(res.total_gpu_seconds, 9),
            sig.hexdigest()[:16]) == (490, 490, 0, 249.312,
                                      "af766f3924e39378")


# --------------------------------------------------------------------------
# Batch-model calibration (fit_batch_model wired through the planner)
# --------------------------------------------------------------------------
def test_solve_c_batch_preserves_engine_semantics():
    """The split engine sizes its solve at cost.c_batch (it executes
    groups batched) — `solve_c_batch` must reproduce the pre-planner
    `solve_n_cloud(r_dev, cost, rtt)` default bit-exactly, including
    through serialization + replay."""
    from repro.core.cost_model import quantize_step, solve_n_cloud
    cost = CostParams(r_cloud=40.0, n_total=50, n_step=5, t_lim=8.5,
                      k_decode=1.0, c_batch=1.6)
    planner = Planner(cost, policy="variable", solve_c_batch=cost.c_batch)
    for r_dev, rtt in ((1.5, 0.05), (2.25, 0.3), (4.0, 0.1)):
        legacy_n = solve_n_cloud(r_dev, cost, rtt)   # default cb=c_batch
        legacy = quantize_step(legacy_n, cost.n_step, cost.n_total)
        d = planner.plan(PlanRequest(
            device=DeviceProfile("d", r_dev=r_dev, rtt=rtt)))
        assert d.n_exact == legacy_n
        assert d.n_final == legacy
        assert replay(d.to_json()).to_json() == d.to_json()
    # at c_batch=1.6 this genuinely differs from the solo-rate solve
    solo = Planner(cost, policy="variable")
    assert solo.plan(PlanRequest(
        device=DeviceProfile("d", r_dev=1.5, rtt=0.05))).n_final != \
        planner.plan(PlanRequest(
            device=DeviceProfile("d", r_dev=1.5, rtt=0.05))).n_final


def test_batch_model_rejects_decreasing_timings():
    """A fit with negative t_task (batch times shrinking with b) must
    fail loudly, not produce c_batch < 1 / negative service times."""
    with pytest.raises(ValueError):
        BatchModel.from_timings([(1, 0.02), (2, 0.01)])
    with pytest.raises(ValueError):
        BatchModel(t_startup=0.03, t_task=-0.01)
    with pytest.raises(ValueError):
        BatchModel(t_startup=0.0, t_task=0.0)
    # repeat measurements at one batch size: no slope to fit
    with pytest.raises(ValueError):
        BatchModel.from_timings([(2, 0.10), (2, 0.11)])


def test_non_audit_plan_matches_audit_values():
    """audit=False (the fleet simulator's hot-loop mode) must produce
    the same decision VALUES as the audited pipeline — it only skips
    the trace/replay payloads and the advisory route."""
    audited = _planner("variable+batching")
    fast = Planner(CALIBRATED, policy="variable+batching", audit=False)
    for r_dev in (1.5, 2.25, 3.0, 50.0):
        a = audited.plan(_request(r_dev=r_dev, hint=0.2))
        f = fast.plan(_request(r_dev=r_dev, hint=0.2))
        assert (f.n_exact, f.n_final, f.latency, f.feasible,
                f.gpu_time, f.batch_admit, f.batch_max_wait, f.t_lim) \
            == (a.n_exact, a.n_final, a.latency, a.feasible,
                a.gpu_time, a.batch_admit, a.batch_max_wait, a.t_lim)
    assert f.trace == [] and f.request == {} and f.planner == {}
    assert a.trace and a.planner
    # non-audit decisions refuse replay with a clear error, not KeyError
    with pytest.raises(ValueError, match="audit=False"):
        f.replay()
    with pytest.raises(ValueError, match="audit=False"):
        PlanDecision.from_json(f.to_json()).assignment()


def test_deadline_floors_clamped_demand_does_not_spill():
    """Demand a max_count-clamped fast class cannot cover must not pin
    slower classes that cannot meet its SLA anyway."""
    cap = CloudCapacity((
        GpuClass("fast", r_cloud=62.5, count=2, max_count=2),
        GpuClass("mid", r_cloud=31.0, count=4, max_count=64),
        GpuClass("slow", r_cloud=10.0, count=4, preemptible=True,
                 cost_weight=0.2, max_count=64),
    ))
    # heavy demand feasible ONLY on the fast class
    demands = [(35, 2.1, 0.3)] * 600
    floors = deadline_floors(demands, CALIBRATED, cap, horizon_s=30.0,
                             headroom=1.3, c_batch=1.6)
    assert floors["fast"] == 2          # clamped at max_count
    assert floors["mid"] == 0           # residual must not spill here
    assert floors["slow"] == 0


def test_config_cache_invalidated_by_set_t_lim():
    planner = _planner("variable")
    before = planner.plan(_request()).planner
    planner.set_t_lim(20.0)
    after = planner.plan(_request()).planner
    assert before["params"]["t_lim"] == CALIBRATED.t_lim
    assert after["params"]["t_lim"] == 20.0


def test_batch_model_fit_recovers_constants():
    model = BatchModel.from_timings([(1, 0.026), (2, 0.036), (4, 0.056),
                                     (8, 0.096)])
    assert model.t_startup == pytest.approx(0.016, abs=1e-12)
    assert model.t_task == pytest.approx(0.010, abs=1e-12)
    assert model.c_batch(2) == pytest.approx(0.036 / 0.026)
    assert model.c_batch(1) == 1.0


def test_planner_uses_fitted_batch_slope():
    """batch_timings on the planner replaces the pinned c_batch_at
    extrapolation with the fitted c_batch_of slope — visibly different
    at batch 3 when the measured points disagree with the pin."""
    timings = [(1, 0.026), (2, 0.036), (4, 0.056)]
    model = BatchModel.from_timings(timings)
    planner = Planner(CALIBRATED, policy="variable+batching",
                      batch_model=model)
    assert planner.c_batch_of(3) == pytest.approx(
        c_batch_of(3, 0.016, 0.010))
    assert planner.c_batch_of(3) != c_batch_at(CALIBRATED.c_batch, 3)
    # scheduler and admission share the same fitted constants
    assert planner.scheduler.c_batch_measured == \
        pytest.approx(model.c_batch_2)
    assert planner.admission is not None
    assert planner.admission.c_batch == pytest.approx(model.c_batch(2))
    # and the model replays through the serialized decision
    d = planner.plan(_request())
    assert d.planner["batch_model"] == {"t_startup": model.t_startup,
                                        "t_task": model.t_task}
    assert replay(d.to_json()).to_json() == d.to_json()


def test_fleet_sim_accepts_batch_timings():
    """SimConfig.batch_timings drives batched jobs at the fitted rate:
    a batched pair's GPU-second share is n * c_fit(2) / r_cloud / 2."""
    fleet = [DeviceProfile(device_id="d", r_dev=2.5,
                           k_decode=CALIBRATED.k_decode)]
    timings = [(1, 0.0260), (2, 0.0370), (4, 0.0590)]
    c2 = BatchModel.from_timings(timings).c_batch(2)
    cfg = SimConfig(policy="variable+batching", rate=40.0, duration=20.0,
                    seed=2, fleet=fleet, gpus_init=40, max_gpus=64,
                    batch_timings=timings)
    res = run_fleet_sim(cfg)
    batched = [c for c in res.completed if c.batched]
    assert batched
    n = batched[0].n_final
    assert batched[0].gpu_seconds == pytest.approx(
        n * c2 / CALIBRATED.r_cloud / 2.0)


def test_dryrun_batch_calibration_helpers():
    from repro.launch.dryrun import fit_batch_calibration, parse_batch_times
    pairs = parse_batch_times("1:0.026,2:0.036,4:0.056")
    assert pairs == ((1, 0.026), (2, 0.036), (4, 0.056))
    cal = fit_batch_calibration(pairs)
    assert cal["t_startup"] == pytest.approx(0.016, abs=1e-12)
    assert cal["c_batch"]["2"] == pytest.approx(0.036 / 0.026)
    with pytest.raises(ValueError):
        parse_batch_times("2:0.036")


# --------------------------------------------------------------------------
# Deadline-aware allocation (the docs/capacity.md starvation caveat)
# --------------------------------------------------------------------------
def _two_class(base_count=8, spot_count=8, base_max=64, spot_max=64):
    return CloudCapacity((
        GpuClass("base", r_cloud=CALIBRATED.r_cloud, count=base_count,
                 min_count=1, max_count=base_max),
        GpuClass("spot", r_cloud=CALIBRATED.r_cloud * 0.5,
                 count=spot_count, preemptible=True, cost_weight=0.3,
                 max_count=spot_max),
    ))


def _tight_demands(n=400):
    """Demand that is infeasible on the 0.5x spot class (batched or
    solo) but feasible on base: the starvation scenario."""
    out = []
    for i in range(n):
        r_dev = 2.0 + 0.01 * (i % 10)
        out.append((35, r_dev, 0.3))
    return out


def test_deadline_floors_pin_reserved_class():
    cap = _two_class()
    demands = _tight_demands()
    floors = deadline_floors(demands, CALIBRATED, cap, horizon_s=30.0,
                             headroom=1.3, c_batch=1.6)
    # tight demand can only run on base: the floor covers it there
    assert floors["base"] > 8
    # the slowest class never gets a floor (aggregate supply is the
    # reference plan's job)
    assert floors["spot"] == 0


def test_deadline_floors_homogeneous_are_zero():
    cap = CloudCapacity.from_scalar(CALIBRATED.r_cloud, count=8)
    floors = deadline_floors(_tight_demands(), CALIBRATED, cap,
                             horizon_s=30.0, headroom=1.3)
    assert floors == {"default": 0}


def test_allocator_grows_reserved_class_for_tight_demand():
    """The caveat fix end-to-end at the allocator level: with demands,
    spot-first scaling no longer starves base; without, it does."""
    cap = _two_class()
    demands = _tight_demands()
    wg = group_workloads(n for n, _, _ in demands)
    summary = ScheduleSummary(name="x", assignments=[], total_gpu_time=0.0,
                              latencies=[], violations=0,
                              group_workloads=wg)
    current = {"base": 8, "spot": 8}
    kw = dict(horizon_s=30.0, headroom=1.3)
    blind = allocate_gpus_heterogeneous(summary, CALIBRATED, cap,
                                        current, **kw)
    aware = allocate_gpus_heterogeneous(summary, CALIBRATED, cap, current,
                                        demands=demands,
                                        demand_c_batch=1.6, **kw)
    assert blind.targets["base"] == 8          # starved: spot has headroom
    assert aware.targets["base"] > 8           # feasibility floor grew it
    assert aware.floors["base"] == aware.targets["base"] or \
        aware.targets["base"] >= aware.floors["base"]
    # supply still covers the reference need in both plans
    assert cap.supply(aware.targets) >= aware.needed_supply - 1e-6


def _check_homogeneous_plan_unchanged(n_gpus, w, horizon, headroom):
    """Property: on a homogeneous pool the demand-aware plan is EXACTLY
    the legacy scalar plan (the golden-trace anchor)."""
    cap = CloudCapacity.from_scalar(CALIBRATED.r_cloud, count=n_gpus)
    demands = [(w, 2.25, 0.3)] * 40
    wg = group_workloads(n for n, _, _ in demands)
    summary = ScheduleSummary(name="x", assignments=[], total_gpu_time=0.0,
                              latencies=[], violations=0,
                              group_workloads=wg)
    current = {"default": n_gpus}
    kw = dict(horizon_s=horizon, headroom=headroom)
    legacy = allocate_gpus_heterogeneous(summary, CALIBRATED, cap,
                                         current, **kw)
    aware = allocate_gpus_heterogeneous(summary, CALIBRATED, cap, current,
                                        demands=demands,
                                        demand_c_batch=1.6, **kw)
    assert aware.targets == legacy.targets


@pytest.mark.parametrize("n_gpus,w", [(2, 35), (8, 50), (24, 5)])
def test_homogeneous_plan_unchanged_fixed(n_gpus, w):
    _check_homogeneous_plan_unchanged(n_gpus, w, horizon=30.0, headroom=1.3)


@given(n_gpus=st.integers(1, 64), w=st.integers(0, 50),
       horizon=st.floats(5.0, 120.0), headroom=st.floats(1.0, 2.0))
@settings(max_examples=30, deadline=None)
def test_homogeneous_plan_unchanged_property(n_gpus, w, horizon, headroom):
    _check_homogeneous_plan_unchanged(n_gpus, w, horizon, headroom)


def test_deadline_floors_track_effective_t_lim():
    """A relaxed SLA makes spot feasible again: floors must follow the
    t_lim new arrivals are solved for, not the initial one (the
    adaptive-SLA wiring bug class)."""
    import dataclasses
    cap = _two_class()
    demands = _tight_demands()
    tight = deadline_floors(demands, CALIBRATED, cap, horizon_s=30.0,
                            headroom=1.3, c_batch=1.6)
    relaxed_p = dataclasses.replace(CALIBRATED, t_lim=20.0)
    relaxed = deadline_floors(demands, relaxed_p, cap, horizon_s=30.0,
                              headroom=1.3, c_batch=1.6)
    assert tight["base"] > 8
    assert relaxed["base"] == 0        # everything fits on spot at 20s


def test_adaptive_sla_with_hetero_capacity_runs():
    """§7 adaptive SLA + multi-class capacity + deadline-aware floors
    together: the run must terminate with every arrival completed."""
    cap = table4_capacity(base_count=4, spot_count=8, base_max=16,
                          spot_max=32, spot_ratio=0.5)
    cfg = SimConfig(policy="variable+batching", process="bursty",
                    rate=20.0, duration=60.0, seed=3, capacity=cap,
                    dispatch="edf", adaptive_sla=True, sla_ceil=30.0)
    res = run_fleet_sim(cfg)
    assert len(res.completed) == res.n_arrivals > 0
    assert res.final_t_lim >= CALIBRATED.t_lim


def test_fleet_sim_reserved_class_grows_at_spot_half_rate():
    """End-to-end caveat fix (examples/continuous_serving.py at
    spot_ratio=0.5): under diurnal load with 0.5x spot, the reserved
    base class must grow past its initial count instead of saturating
    while spot sits idle."""
    cap = table4_capacity(base_count=8, spot_count=8, base_max=32,
                          spot_max=64, spot_ratio=0.5)
    cfg = SimConfig(policy="variable+batching", params=CALIBRATED,
                    process="diurnal", rate=20.0, duration=120.0,
                    diurnal_period_s=120.0, seed=0, capacity=cap,
                    dispatch="edf", metrics_interval_s=30.0)
    res = run_fleet_sim(cfg)
    assert res.per_class["base"]["peak"] > cap["base"].count
    assert res.violation_rate() < 0.15


# --------------------------------------------------------------------------
# plan_counts floors plumbing (capacity level)
# --------------------------------------------------------------------------
def test_plan_counts_respects_floors():
    cap = _two_class(base_count=2, spot_count=2)
    # floors raise the base start; release never drops below them
    targets = cap.plan_counts(10 * CALIBRATED.r_cloud,
                              current={"base": 2, "spot": 2},
                              floors={"base": 6})
    assert targets["base"] >= 6
    # zero-need release run: base stays at its floor, not min_count
    targets = cap.plan_counts(0.0, current={"base": 8, "spot": 8},
                              floors={"base": 5})
    assert targets["base"] == 5
    assert targets["spot"] == 0
    # floors clamp at max_count
    targets = cap.plan_counts(0.0, current={"base": 2, "spot": 2},
                              floors={"base": 10_000})
    assert targets["base"] == 64
