"""Simulation core v2: cohort-vectorized planning, the bucketed event
wheel, and the chunked fast lane (docs/sim_core_v2.md).

Covers the PR acceptance criteria:

  * ``cost_model.solve_n_cloud_batch`` equals the scalar closed form
    **bitwise** over randomized grids, including the degenerate edges
    (device-only feasible, cloud-not-faster crossover, n_total cap).
  * ``Planner.plan_cohort`` produces the same decisions as per-profile
    ``plan_profile`` (the cohort entries feed the same verdict paths).
  * the ``EventWheel`` orders exactly across buckets and FIFO within
    one, and tolerates pushes landing in the draining bucket.
  * v2 pins its own golden baseline (the v1 golden trace stays pinned,
    untouched, in test_fleet_sim.py); the chunked fast lane is
    event-dynamics-identical to the generic wheel path on the golden
    config.
  * v1 stays the oracle: v2 aggregate distributions (completions,
    violation rate, GPU-seconds, P² p50/p99) agree within tolerance
    across seeds and arrival processes (the two cores draw different
    rng streams, so equality is distributional, never per-event).
  * a v2 run with ``trace_out`` passes ``replay.verify_decisions``
    field-exactly on TRACE_FIELDS.
  * ``StreamingLatencyStats.merge``/``add_many`` (the v2 shard path)
    agree with a single scalar-add stream.
"""
import hashlib
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import (
    CostParams,
    solve_n_cloud,
    solve_n_cloud_batch,
)
from repro.core.planner import TRACE_FIELDS, PlanCache, Planner
from repro.core.telemetry import P2Quantile, StreamingLatencyStats
from repro.serving.event_wheel import EventWheel
from repro.serving.fleet_sim import (
    FleetSimulator,
    FleetSimulatorV2,
    SimConfig,
    run_fleet_sim,
)
from repro.serving.replay import read_trace, verify_decisions
from repro.serving.simulator import CALIBRATED, table4_fleet

GOLDEN = dict(policy="variable+batching", rate=12.0, duration=40.0,
              seed=7, gpus_init=10, max_gpus=32, metrics_interval_s=10.0)


# --------------------------------------------------------------------------
# closed form: batch == scalar, bitwise
# --------------------------------------------------------------------------
def _params(r_cloud=100.0, n_total=1000, n_step=100, t_lim=10.0,
            k_decode=1.0, c_batch=1.0):
    return CostParams(r_cloud=r_cloud, n_total=n_total, n_step=n_step,
                      t_lim=t_lim, k_decode=k_decode, c_batch=c_batch)


def _assert_batch_matches_scalar(r_devs, t_nets, p, c_batch=None):
    got = solve_n_cloud_batch(np.array(r_devs, np.float64),
                              np.array(t_nets, np.float64), p,
                              c_batch=c_batch)
    for i, (rd, tn) in enumerate(zip(r_devs, t_nets)):
        want = solve_n_cloud(rd, p, tn, c_batch=c_batch)
        assert float(got[i]) == want, (
            f"lane {i}: batch {float(got[i])!r} != scalar {want!r} "
            f"(r_dev={rd}, t_network={tn})")


@pytest.mark.parametrize("case", [
    # interior solutions around the Table-4 regime
    dict(r_devs=[5.0, 20.0, 80.0, 150.0], t_nets=[0.05, 0.2, 0.5, 1.0]),
    # rhs >= 0: device alone meets the SLA -> 0.0 lanes
    dict(r_devs=[500.0, 1000.0], t_nets=[0.0, 0.1],
         p=_params(t_lim=100.0)),
    # denom >= 0: device faster than cloud/c_batch -> n_total lanes
    dict(r_devs=[500.0, 90.0], t_nets=[0.5, 0.5],
         p=_params(r_cloud=50.0, c_batch=2.0, t_lim=2.0)),
    # n_total cap: SLA so tight even all-cloud clips
    dict(r_devs=[1.0, 2.0], t_nets=[5.0, 8.0], p=_params(t_lim=0.5)),
    # zero-iteration job edge
    dict(r_devs=[5.0, 50.0], t_nets=[0.1, 0.1], p=_params(n_total=0)),
    # per-call c_batch override (the admission's batched solve)
    dict(r_devs=[5.0, 20.0, 80.0], t_nets=[0.1, 0.3, 0.9], c_batch=1.6),
])
def test_solve_n_cloud_batch_matches_scalar_fixed(case):
    p = case.get("p", _params())
    _assert_batch_matches_scalar(case["r_devs"], case["t_nets"], p,
                                 c_batch=case.get("c_batch"))


@settings(max_examples=200, deadline=None)
@given(
    r_devs=st.lists(st.floats(0.1, 1000.0), min_size=1, max_size=32),
    t_net=st.floats(0.0, 20.0),
    r_cloud=st.floats(1.0, 5000.0),
    n_total=st.integers(0, 5000),
    t_lim=st.floats(0.01, 100.0),
    k_decode=st.floats(0.0, 10.0),
    c_batch=st.floats(0.5, 4.0),
)
def test_solve_n_cloud_batch_matches_scalar_property(
        r_devs, t_net, r_cloud, n_total, t_lim, k_decode, c_batch):
    """The batch kernel IS the closed form: every lane bit-identical to
    the scalar transcription, whatever branch it lands on."""
    p = _params(r_cloud=r_cloud, n_total=n_total, t_lim=t_lim,
                k_decode=k_decode, c_batch=c_batch)
    t_nets = [t_net + 0.01 * i for i in range(len(r_devs))]
    _assert_batch_matches_scalar(r_devs, t_nets, p)


# --------------------------------------------------------------------------
# cohort planning == scalar planning
# --------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["variable", "variable+batching",
                                    "constant", "all_cloud"])
@pytest.mark.parametrize("cache", [None, "plain", "quantized"])
def test_plan_cohort_matches_plan_profile(policy, cache):
    fleet = table4_fleet(seed=3, params=CALIBRATED)[:200]
    mk_cache = {"plain": lambda: PlanCache(),
                "quantized": lambda: PlanCache(quanta=(0.5, 0.05, 1e6)),
                None: lambda: None}[cache]
    worst = max(pr.rtt for pr in fleet)
    cohort = Planner(CALIBRATED, policy=policy, worst_rtt=worst,
                     audit=False, cache=mk_cache())
    scalar = Planner(CALIBRATED, policy=policy, worst_rtt=worst,
                     audit=False, cache=mk_cache())
    qd, util = 0.37, 0.5
    got = cohort.plan_cohort(fleet, qd, util)
    want = [scalar.plan_profile(pr, qd, util) for pr in fleet]
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.to_trace_json() == w.to_trace_json()
        assert (g.batch_admit, g.batch_max_wait, g.batch_latency) \
            == (w.batch_admit, w.batch_max_wait, w.batch_latency)


def test_plan_cohort_requires_hot_loop_mode():
    planner = Planner(CALIBRATED, policy="variable+batching",
                      worst_rtt=1.0)        # audit=True default
    with pytest.raises(ValueError):
        planner.plan_cohort(table4_fleet(seed=0, params=CALIBRATED)[:2])


# --------------------------------------------------------------------------
# EventWheel
# --------------------------------------------------------------------------
def _drain(wheel):
    """Drain the wheel the way the v2 core does: smallest bucket index
    first, FIFO (by position) within the bucket — buckets may grow while
    draining."""
    out = []
    while wheel.order:
        idx = heapq_pop(wheel.order)
        bucket = wheel.buckets[idx]
        i = 0
        while i < len(bucket):
            out.append(bucket[i])
            i += 1
        del wheel.buckets[idx]
    return out


def heapq_pop(heap):
    import heapq
    return heapq.heappop(heap)


def test_event_wheel_orders_across_buckets_fifo_within():
    w = EventWheel(1.0)
    w.push(2.5, 1, "c")
    w.push(0.2, 1, "a1")
    w.push(0.9, 1, "a2")     # same bucket as a1, pushed later
    w.push(0.1, 1, "a3")     # same bucket, later still: FIFO not sorted
    w.push(1.5, 1, "b")
    assert len(w) == 5 and bool(w)
    got = [payload for _, _, payload in _drain(w)]
    assert got == ["a1", "a2", "a3", "b", "c"]
    assert len(w) == 0 and not bool(w)


def test_event_wheel_push_during_drain_lands_in_future_bucket():
    w = EventWheel(1.0)
    w.push(0.5, 0, "first")
    idx = heapq_pop(w.order)
    bucket = w.buckets[idx]
    seen = []
    i = 0
    while i < len(bucket):
        t, _, payload = bucket[i]
        seen.append(payload)
        if payload == "first":
            w.push(t + 0.1, 0, "same-bucket")   # grows the live bucket
            w.push(t + 5.0, 0, "later")
        i += 1
    del w.buckets[idx]
    assert seen == ["first", "same-bucket"]
    assert [p for _, _, p in _drain(w)] == ["later"]


def test_event_wheel_bulk_push_and_width_validation():
    with pytest.raises(ValueError):
        EventWheel(0.0)
    w = EventWheel(0.25)
    w.push_times([0.1, 0.2, 0.6, 2.0], kind=2)
    assert len(w) == 4
    assert sorted(w.buckets) == [0, 2, 8]
    assert [t for t, _, _ in w.buckets[0]] == [0.1, 0.2]


# --------------------------------------------------------------------------
# v2 golden baselines (v1's pin lives in test_fleet_sim.py, untouched)
# --------------------------------------------------------------------------
def _digest(res):
    sig = hashlib.sha256()
    for c in res.completed:
        sig.update(f"{c.request_id}:{c.completion:.9f}:{c.batched:d};"
                   .encode())
    return sig.hexdigest()[:16]


def test_v2_golden_trace():
    """v2's own pinned baseline (exact-record mode exercises the wheel
    loop).  v2 draws a different arrival rng stream than v1, so these
    numbers differ from the v1 golden trace by design; what this test
    guards is v2-to-v2 drift.  Re-record alongside the v1 pin when a
    deliberate semantic change moves them (docs/sim_core_v2.md)."""
    res = run_fleet_sim(SimConfig(core="v2", **GOLDEN))
    golden = {
        "n_arrivals": res.n_arrivals,
        "n_completed": len(res.completed),
        "violations": res.violations,
        "gpu_seconds": round(res.total_gpu_seconds, 9),
        "p99": round(res.latency_percentile(99), 9),
        "digest": _digest(res),
    }
    assert golden == V2_GOLDEN


V2_GOLDEN = {
    "n_arrivals": 465,
    "n_completed": 465,
    "violations": 4,
    "gpu_seconds": 236.352,
    "p99": 8.494425237,
    "digest": "0a11408760296ce3",
}


def test_v2_fast_lane_matches_wheel_path():
    """The chunked fast lane is an exact re-expression of the generic
    wheel loop on its eligible configs: same arrivals, violations,
    GPU-seconds and completion count (stats shard ingest order differs,
    so P² percentiles are compared loosely)."""
    for seed in (7, 1, 2):
        cfg = SimConfig(core="v2", exact_stats=False,
                        **{**GOLDEN, "seed": seed})
        fast_sim = FleetSimulatorV2(cfg)
        assert fast_sim._fast_eligible()
        fast = fast_sim.run()
        wheel_sim = FleetSimulatorV2(cfg)
        wheel_sim._fast_eligible = lambda: False
        wheel = wheel_sim.run()
        assert fast.n_arrivals == wheel.n_arrivals
        assert fast.violations == wheel.violations
        assert fast.n_completed() == wheel.n_completed()
        assert abs(fast.total_gpu_seconds
                   - wheel.total_gpu_seconds) < 1e-9
        for q in (50, 99):
            a, b = fast.latency_percentile(q), wheel.latency_percentile(q)
            assert abs(a - b) <= 0.05 * max(abs(a), abs(b), 1e-9)


def test_v2_fast_lane_timeseries_invariants():
    """The fast lane's snapshots keep v1's conservation law: every
    arrival is completed, in flight, queued, or windowed at each tick."""
    res = run_fleet_sim(SimConfig(core="v2", exact_stats=False, **GOLDEN))
    assert len(res.timeseries) >= 3
    for snap in res.timeseries:
        assert snap["completed"] + snap["in_flight"] == snap["arrivals"]
        assert snap["gpus"] >= snap["gpus_busy"] >= 0
        assert 0.0 <= snap["utilization"] <= 1.0 + 1e-9
    for a, b in zip(res.timeseries, res.timeseries[1:]):
        assert b["arrivals"] >= a["arrivals"]
        assert b["violations"] >= a["violations"]
        assert b["gpu_seconds"] >= a["gpu_seconds"] - 1e-12


def test_v1_core_unaffected_by_v2_machinery():
    """core="v1" (the default) stays the pinned golden trace — the v2
    subsystem must be completely inert for v1 configs."""
    res = run_fleet_sim(SimConfig(**GOLDEN))
    assert (res.n_arrivals, len(res.completed), res.violations,
            round(res.total_gpu_seconds, 9), _digest(res)) == \
        (490, 490, 0, 249.312, "af766f3924e39378")


# --------------------------------------------------------------------------
# v1 as oracle: aggregate distributions within tolerance
# --------------------------------------------------------------------------
ORACLE = dict(policy="variable+batching", rate=60.0, duration=40.0,
              gpus_init=30, max_gpus=80, metrics_interval_s=10.0)
#: documented in docs/sim_core_v2.md: the cores draw different arrival
#: rng streams, so aggregates agree distributionally.  Count tolerance
#: covers two independent Poisson draws (~3 sd of the difference);
#: violation rate is compared absolutely (borderline-SLA configs flip
#: whole windows); GPU-seconds ride the completion count.
COUNT_RTOL = 0.10
VIOL_ATOL = 0.05
GPU_PER_REQ_RTOL = 0.05
PCTL_RTOL = 0.15


@pytest.mark.parametrize("process", ["poisson", "bursty", "diurnal"])
@pytest.mark.parametrize("seed", [0, 3])
def test_v2_aggregates_match_v1_oracle(process, seed):
    r1 = run_fleet_sim(SimConfig(process=process, seed=seed,
                                 exact_stats=False, **ORACLE))
    r2 = run_fleet_sim(SimConfig(process=process, seed=seed, core="v2",
                                 exact_stats=False, **ORACLE))
    n1, n2 = r1.n_completed(), r2.n_completed()
    assert n1 > 0 and n2 > 0
    assert abs(n1 - n2) <= COUNT_RTOL * max(n1, n2)
    v1_rate = r1.violations / n1
    v2_rate = r2.violations / n2
    assert abs(v1_rate - v2_rate) <= VIOL_ATOL
    g1 = r1.total_gpu_seconds / n1
    g2 = r2.total_gpu_seconds / n2
    assert abs(g1 - g2) <= GPU_PER_REQ_RTOL * max(g1, g2)
    for q in (50, 99):
        p1, p2 = r1.latency_percentile(q), r2.latency_percentile(q)
        assert abs(p1 - p2) <= PCTL_RTOL * max(abs(p1), abs(p2))


# --------------------------------------------------------------------------
# decision-trace replay (field-exact on TRACE_FIELDS)
# --------------------------------------------------------------------------
def test_v2_trace_passes_verify_decisions(tmp_path):
    """Every decision a v2 run records re-derives exactly through a
    planner rebuilt from the trace header — the cohort-solved entries
    are bit-identical to the scalar pipeline's."""
    path = str(tmp_path / "v2.jsonl")
    res = run_fleet_sim(SimConfig(core="v2", trace_out=path, **GOLDEN))
    trace = read_trace(path)
    assert len(trace.plans()) == res.n_arrivals
    for rec in trace.plans():
        assert set(rec["decision"]) == set(TRACE_FIELDS)
    report = verify_decisions(trace)
    assert report.n_plans == res.n_arrivals
    assert report.ok, report.to_json()


# --------------------------------------------------------------------------
# streaming-stats shards: merge()/add_many == one scalar stream
# --------------------------------------------------------------------------
def _lognormal(seed, n):
    rng = np.random.default_rng(seed)
    return [float(x) for x in rng.lognormal(1.0, 0.5, n)]


def test_add_many_equals_scalar_adds():
    xs = _lognormal(11, 4000)
    one = StreamingLatencyStats()
    for i, x in enumerate(xs):
        one.add(x, batched=(i % 3 == 0))
    bulk = StreamingLatencyStats()
    step = 257
    for lo in range(0, len(xs), step):
        chunk = xs[lo:lo + step]
        nb = sum(1 for i in range(lo, lo + len(chunk)) if i % 3 == 0)
        bulk.add_many(chunk, nb)
    bulk.add_many([], 0)                      # no-op by contract
    assert (bulk.count, bulk.batched) == (one.count, one.batched)
    # sum folds per chunk (builtin sum) vs per element: same value up
    # to float summation order
    assert math.isclose(bulk.sum, one.sum, rel_tol=1e-12)
    assert bulk.max == one.max
    for q in (50.0, 99.0):                    # same ingest order: exact
        assert bulk.percentile(q) == one.percentile(q)


def test_merged_shards_match_single_stream_within_p2_tolerance():
    """The v2 cohort path folds round-robin shards with merge(); the
    result must agree with one scalar stream over the same data within
    the P² estimator's own accuracy."""
    xs = _lognormal(5, 20000)
    single = StreamingLatencyStats()
    shards = [StreamingLatencyStats() for _ in range(4)]
    for i, x in enumerate(xs):
        b = i % 5 == 0
        single.add(x, b)
        shards[i % 4].add(x, b)
    merged = StreamingLatencyStats()
    for s in shards:
        merged.merge(s)
    assert merged.count == single.count == len(xs)
    assert merged.batched == single.batched
    assert abs(merged.sum - single.sum) < 1e-6 * single.sum
    assert merged.max == single.max
    for q in (50.0, 99.0):
        exact = float(np.percentile(xs, q))
        assert abs(merged.percentile(q) - exact) <= 0.05 * exact
        assert (abs(merged.percentile(q) - single.percentile(q))
                <= 0.05 * exact)


def test_p2_merge_exact_while_small():
    a, b = P2Quantile(0.5), P2Quantile(0.5)
    for x in (1.0, 5.0):
        a.add(x)
    for x in (2.0, 4.0, 3.0):
        b.add(x)
    a.merge(b)
    assert a.n == 5
    assert a.value() == 3.0                   # exact sample median
    with pytest.raises(ValueError):
        a.merge(P2Quantile(0.99))
