"""Multiprocess cohort-sharded simulation (serving/shard_sim.py;
docs/sim_core_v2.md, "Multiprocess sharding").

Covers the PR acceptance criteria:

  * P-invariance: ``processes`` in {1, 2, 4} produce BIT-IDENTICAL
    results — counters, GPU-seconds, P² percentiles, per-shard records
    and metric rows.  The simulation depends only on
    ``(seed, shard_cohorts)``, never on the worker count: cohorts own
    private rng substreams and every coordinator fold walks cohorts in
    id order.
  * the sharded lane pins its own golden anchor (the plain-v2 golden in
    test_sim_core_v2.py stays pinned, untouched — processes=1 without
    shard_cohorts never enters the shard path).
  * sharded aggregates match the plain v2 fast lane AND the v1 oracle
    within the documented tolerances at moderate-to-high per-lane rates
    (low per-lane rates dilute batching windows; see the doc).
  * fast-lane blockers still fall back loudly to the wheel (result
    reports processes=1, no shard records) and ``v2_fast="require"``
    raises — sharding is never silently dropped.
  * config validation, ``slice_evenly`` and the deterministic
    provision-split helper.
"""
import math

import pytest

from repro.core.capacity import slice_evenly
from repro.serving.fleet_sim import SimConfig, run_fleet_sim
from repro.serving.shard_sim import _distribute_add

#: Moderate-to-high rate on purpose: each of the 4 cohort lanes sees
#: rate/4 = 150 req/s, enough to keep batching windows filling at the
#: same cadence as the unsharded lane (the doc's low-rate caveat).
SHARD = dict(policy="variable+batching", rate=600.0, duration=40.0,
             gpus_init=300, max_gpus=800, metrics_interval_s=10.0,
             core="v2", exact_stats=False)

#: Pinned sharded-lane anchor (seed 7, shard_cohorts=4): any worker
#: count must reproduce these numbers bitwise.
SHARD_GOLDEN = dict(
    n_arrivals=24093, n_completed=24093, violations=678,
    total_gpu_seconds=12088.415999999545, peak_gpus=549, final_gpus=549,
    released_gpus=0, n_events=72396,
    p50=7.813435694774972, p99=8.610533060619176,
    utilization=0.5315926121371831)

#: Same rationale as test_sim_core_v2.ORACLE tolerances: cohorts draw
#: independent arrival substreams, so agreement is distributional.
COUNT_RTOL = 0.10
VIOL_ATOL = 0.05
GPU_PER_REQ_RTOL = 0.05
PCTL_RTOL = 0.15


def _sharded(processes, seed=7, **over):
    cfg = dict(SHARD, seed=seed, shard_cohorts=4, processes=processes)
    cfg.update(over)
    return run_fleet_sim(SimConfig(**cfg))


@pytest.fixture(scope="module")
def shard_runs():
    """One sharded run per worker count; P > 1 spawns real workers."""
    return {p: _sharded(p) for p in (1, 2, 4)}


# --------------------------------------------------------------------------
# P-invariance: bit-identical across worker counts
# --------------------------------------------------------------------------
def test_p_invariant_across_worker_counts(shard_runs):
    a = shard_runs[1]
    for p in (2, 4):
        b = shard_runs[p]
        for f in ("n_arrivals", "violations", "total_gpu_seconds",
                  "peak_gpus", "final_gpus", "released_gpus", "n_events",
                  "utilization", "total_gpu_cost", "per_shard",
                  "timeseries", "shard_chunk_s"):
            assert getattr(a, f) == getattr(b, f), (f, p)
        assert b.processes == p             # run metadata, not simulation
        assert a.n_completed() == b.n_completed()
        for q in (50.0, 99.0):
            assert a.stream.percentile(q) == b.stream.percentile(q)


def test_worker_rss_reported_per_worker(shard_runs):
    # in-process P=1 has no child processes to meter
    assert shard_runs[1].worker_peak_rss_mb == []
    for p in (2, 4):
        rss = shard_runs[p].worker_peak_rss_mb
        assert len(rss) == p
        assert all(x > 0 for x in rss)


def test_per_shard_counters_sum_exactly(shard_runs):
    res = shard_runs[1]
    assert len(res.per_shard) == 4
    assert [s["cohort"] for s in res.per_shard] == [0, 1, 2, 3]
    for key, total in (("arrivals", res.n_arrivals),
                       ("violations", res.violations),
                       ("completed", res.n_completed())):
        assert sum(s[key] for s in res.per_shard) == total
    assert math.isclose(sum(s["gpu_seconds"] for s in res.per_shard),
                        res.total_gpu_seconds, rel_tol=1e-9)


# --------------------------------------------------------------------------
# golden anchor for the sharded lane
# --------------------------------------------------------------------------
def test_sharded_golden_aggregates(shard_runs):
    res = shard_runs[1]
    got = dict(
        n_arrivals=res.n_arrivals, n_completed=res.n_completed(),
        violations=res.violations,
        total_gpu_seconds=res.total_gpu_seconds, peak_gpus=res.peak_gpus,
        final_gpus=res.final_gpus, released_gpus=res.released_gpus,
        n_events=res.n_events, p50=res.stream.percentile(50.0),
        p99=res.stream.percentile(99.0), utilization=res.utilization)
    assert got == SHARD_GOLDEN
    assert res.fast_lane
    assert res.processes == 1
    assert res.shard_chunk_s is not None


# --------------------------------------------------------------------------
# the plain config never enters the shard path
# --------------------------------------------------------------------------
def test_plain_v2_config_skips_shard_path():
    res = run_fleet_sim(SimConfig(policy="variable+batching", rate=12.0,
                                  duration=10.0, seed=7, gpus_init=10,
                                  max_gpus=32, core="v2",
                                  exact_stats=False, processes=1))
    assert res.fast_lane
    assert res.processes == 1
    assert res.shard_chunk_s is None
    assert res.per_shard == []
    assert res.worker_peak_rss_mb == []


# --------------------------------------------------------------------------
# fidelity: plain v2 fast lane and the v1 core as oracles
# --------------------------------------------------------------------------
def _assert_close(ref, res):
    n1, n2 = ref.n_completed(), res.n_completed()
    assert n1 > 0 and n2 > 0
    assert abs(n1 - n2) <= COUNT_RTOL * max(n1, n2)
    assert abs(ref.violations / n1 - res.violations / n2) <= VIOL_ATOL
    g1, g2 = ref.total_gpu_seconds / n1, res.total_gpu_seconds / n2
    assert abs(g1 - g2) <= GPU_PER_REQ_RTOL * max(g1, g2)
    for q in (50, 99):
        p1, p2 = ref.latency_percentile(q), res.latency_percentile(q)
        assert abs(p1 - p2) <= PCTL_RTOL * max(abs(p1), abs(p2))


@pytest.mark.parametrize("seed", [7, 11])
def test_sharded_matches_plain_v2_aggregates(seed):
    ref = run_fleet_sim(SimConfig(seed=seed, **SHARD))
    _assert_close(ref, _sharded(1, seed=seed))


def test_sharded_matches_v1_oracle(shard_runs):
    v1 = dict(SHARD, seed=7)
    del v1["core"]
    _assert_close(run_fleet_sim(SimConfig(**v1)), shard_runs[1])


# --------------------------------------------------------------------------
# loud fallback: blockers win over sharding, "require" raises
# --------------------------------------------------------------------------
def test_blocked_config_falls_back_to_wheel():
    cfg = dict(policy="variable+batching", rate=12.0, duration=10.0,
               seed=7, gpus_init=10, max_gpus=32, core="v2",
               processes=2)                 # exact_stats=True by default
    res = run_fleet_sim(SimConfig(**cfg))
    assert not res.fast_lane
    assert "exact_stats" in res.fast_lane_blockers
    assert res.processes == 1               # sharding never ran
    assert res.per_shard == []
    with pytest.raises(ValueError, match="exact_stats"):
        run_fleet_sim(SimConfig(v2_fast="require", **cfg))


# --------------------------------------------------------------------------
# validation
# --------------------------------------------------------------------------
def _cfg(**kw):
    return SimConfig(policy="variable+batching", rate=5.0, duration=1.0,
                     **kw)


def test_validate_rejects_bad_shard_configs():
    with pytest.raises(ValueError, match="core='v2'"):
        _cfg(processes=2).validate()        # v1 core
    with pytest.raises(ValueError, match="core='v2'"):
        _cfg(shard_cohorts=4).validate()
    with pytest.raises(ValueError, match="processes"):
        _cfg(core="v2", processes=0).validate()
    with pytest.raises(ValueError, match="shard_cohorts"):
        _cfg(core="v2", shard_cohorts=0).validate()
    with pytest.raises(ValueError, match="shard_chunk_s"):
        _cfg(core="v2", shard_chunk_s=0.0).validate()


def test_run_rejects_undersized_fleet_or_capacity():
    with pytest.raises(ValueError, match="fleet size"):
        run_fleet_sim(_cfg(core="v2", exact_stats=False, gpus_init=4,
                           max_gpus=8, shard_cohorts=2000))
    with pytest.raises(ValueError, match="capacity"):
        run_fleet_sim(_cfg(core="v2", exact_stats=False, gpus_init=4,
                           max_gpus=128, shard_cohorts=64))


# --------------------------------------------------------------------------
# deterministic capacity-split helpers
# --------------------------------------------------------------------------
def test_slice_evenly_remainder_to_low_cohorts():
    assert slice_evenly(10, 4) == [3, 3, 2, 2]
    assert slice_evenly(3, 5) == [1, 1, 1, 0, 0]
    assert slice_evenly(8, 2) == [4, 4]
    for total, parts in ((0, 3), (17, 5), (1000, 7)):
        s = slice_evenly(total, parts)
        assert sum(s) == total and len(s) == parts
        assert s == sorted(s, reverse=True)   # low ids get the remainder
    with pytest.raises(ValueError):
        slice_evenly(4, 0)


def test_distribute_add_equalizes_and_is_deterministic():
    assert _distribute_add(5, [3, 1, 1]) == [1, 2, 2]
    assert _distribute_add(0, [3, 1, 1]) == [0, 0, 0]
    give = _distribute_add(7, [2, 2, 2, 2])
    assert sum(give) == 7
    assert give == _distribute_add(7, [2, 2, 2, 2])   # deterministic
    # ties break by cohort id: the extra unit lands on the lowest ids
    assert give == [2, 2, 2, 1]
